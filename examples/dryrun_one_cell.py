"""Lower + compile one (arch x shape) cell on the production mesh and print
its roofline terms.  This is the per-cell version of repro.launch.dryrun.

Run:  PYTHONPATH=src python examples/dryrun_one_cell.py --arch mixtral-8x7b \
          --shape train_4k [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    from repro.launch.roofline import analyse_cell, param_counts, advice

    rec = lower_cell(args.arch, args.shape, args.multi_pod)
    if rec["status"] != "ok":
        print(rec)
        return
    cell = analyse_cell(rec, {args.arch: param_counts(args.arch)})
    print(f"\n{args.arch} x {args.shape} on {rec['mesh']}:")
    print(f"  compute    {cell.compute_s:.3e} s")
    print(f"  memory     {cell.memory_s:.3e} s")
    print(f"  collective {cell.collective_s:.3e} s")
    print(f"  dominant:  {cell.dominant}  (useful ratio {cell.useful_ratio:.2f})")
    print(f"  advice:    {advice(cell)}")


if __name__ == "__main__":
    main()
