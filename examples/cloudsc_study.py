"""The CLOUDSC case study end to end (paper §5).

Run:  PYTHONPATH=src python examples/cloudsc_study.py
"""
import numpy as np
import jax

from repro.cloudsc import erosion_program, mini_cloudsc_program
from repro.cloudsc.erosion import physical_inputs
from repro.cloudsc.scheme import scheme_inputs
from repro.core import Schedule, compile_jax, normalize
from repro.core.util import time_fn


def main() -> None:
    nproma, klev = 128, 137
    p = erosion_program(nproma, klev)
    pn = normalize(p)
    print(f"erosion: scalar temps expanded to "
          f"{[a.shape for a in pn.arrays if a.name in pn.temps]}")
    inp = {k: np.asarray(v, np.float32) for k, v in physical_inputs(nproma, klev).items()}
    f0 = jax.jit(compile_jax(p, Schedule(mode="as_written", use_idioms=False)))
    f1 = jax.jit(compile_jax(pn, Schedule(mode="canonical", use_idioms=False)))
    err = np.abs(np.asarray(f0(inp)["ZTP1"]) - np.asarray(f1(inp)["ZTP1"])).max()
    t0, t1 = time_fn(lambda: f0(inp), repeats=3), time_fn(lambda: f1(inp), repeats=5)
    print(f"erosion nest: original {t0/1e3:.1f} ms -> normalized {t1/1e3:.2f} ms "
          f"({t0/t1:.0f}x, maxerr {err:.1e}; paper Table 1: 6.2x)")

    ps = mini_cloudsc_program(nproma, klev)
    psn = normalize(ps)
    inps = {k: np.asarray(v, np.float32) for k, v in scheme_inputs(nproma, klev).items()}
    g0 = jax.jit(compile_jax(ps, Schedule(mode="as_written", use_idioms=False)))
    g1 = jax.jit(compile_jax(psn, Schedule(mode="canonical", use_idioms=False)))
    t0, t1 = time_fn(lambda: g0(inps), repeats=3), time_fn(lambda: g1(inps), repeats=5)
    print(f"mini scheme:  as-written {t0/1e3:.1f} ms -> daisy {t1/1e3:.2f} ms "
          f"({t0/t1:.1f}x; the JK-carried flux recurrence stays sequential)")
    print("OK")


if __name__ == "__main__":
    main()
