"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a scaled MiniCPM-family config (~100M params, WSD schedule — the
arch's assigned scheduler), the synthetic Zipf pipeline, AdamW, periodic
atomic checkpoints, and the fault-tolerant loop.
"""
import argparse
from dataclasses import replace

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.train_loop import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_100m")
    args = ap.parse_args()

    # ~109M params: 12 layers x d768 of the minicpm family (CPU-trainable;
    # ~300 steps takes ~20-30 min on a 1-core container)
    cfg = replace(
        get_config("minicpm-2b"),
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        d_ff=2048, vocab=32000, dtype="float32", remat="none",
    )
    n_params = (cfg.vocab * cfg.d_model  # embed (tied head)
                + cfg.n_layers * (4 * cfg.d_model * cfg.d_model
                                  + 3 * cfg.d_model * cfg.d_ff))
    print(f"model: {cfg.name}-scaled, ~{n_params / 1e6:.0f}M params")

    dcfg = DataConfig(seq_len=args.seq_len, global_batch=args.batch,
                      vocab=cfg.vocab, seed=0)
    ocfg = AdamWConfig(lr=6e-4, schedule="wsd", warmup_steps=20,
                       total_steps=args.steps)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100, accum_steps=1)
    tr = Trainer(cfg, ocfg, dcfg, tcfg)
    tr.try_restore()
    hist = tr.run(args.steps - tr.step if tr.step < args.steps else 0)
    if hist:
        first = sum(h["loss"] for h in hist[:10]) / min(10, len(hist))
        last = sum(h["loss"] for h in hist[-10:]) / min(10, len(hist))
        dts = sorted(h["dt"] for h in hist)
        print(f"loss: {first:.3f} -> {last:.3f} over {len(hist)} steps "
              f"(median {dts[len(dts)//2]*1e3:.0f} ms/step)")
        assert last < first, "loss must decrease"
    print("OK")


if __name__ == "__main__":
    main()
