"""Quickstart: normalize a loop nest and schedule it with daisy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    Array, Computation, Loop, Program, acc, Daisy, execute_numpy, fingerprint,
    normalize,
)
from repro.core.scheduler import random_inputs

# -- 1. author a loop nest (the paper's Fig. 1 "gemm_2": bad loop order) -----
NI, NJ, NK = 256, 256, 256
scale = Computation("scale", acc("C", "i", "j"), (acc("C", "i", "j"),),
                    lambda c: 1.2 * c)
mac = Computation("mac", acc("C", "i2", "j2"),
                  (acc("A", "i2", "k"), acc("B", "k", "j2")),
                  lambda a, b: 1.5 * a * b, accumulate="+")
prog = Program(
    "my_gemm",
    (Array("A", (NI, NK)), Array("B", (NK, NJ)), Array("C", (NI, NJ))),
    (
        Loop("i", NI, body=(Loop("j", NJ, body=(scale,)),)),
        Loop("j2", NJ, body=(Loop("k", NK, body=(Loop("i2", NI, body=(mac,)),)),)),
    ),
)

# -- 2. a priori normalization: maximal fission + stride minimization --------
norm = normalize(prog)
print("canonical nests:")
for nest in norm.body:
    print("  ", fingerprint(nest)[:100])

# -- 3. schedule through daisy (idiom detection + transfer tuning) -----------
daisy = Daisy()
print(daisy.explain(prog).report())       # per-pass wall time + nest deltas
daisy.seed([prog], search=False)          # seed the database from this program
fn, plan = daisy.compile(prog)            # normalize -> DB lookup -> lower
for p in plan.nests:
    print(f"nest idiom={p.idiom:12s} recipe={p.recipe.kind:10s} source={p.source}")

# -- 4. run it and check against the interpreter oracle ----------------------
inp = random_inputs(prog, seed=0)
out = fn(inp)
ref = execute_numpy(prog, {k: v.astype(np.float64) for k, v in inp.items()})
err = np.abs(np.asarray(out["C"], np.float64) - ref["C"]).max()
print(f"max |err| vs oracle: {err:.2e}")
assert err < 1e-2
print("OK")
