"""Batched serving with the continuous-batching engine.

Demonstrates the request-handle lifecycle: ``submit(prompt)`` returns a
:class:`RequestHandle` immediately; the engine decodes every occupied slot
with one batched step per ``step()`` call, streaming tokens into an
optional per-request callback, and ``drain()`` runs the queue dry.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serve import ServeConfig, ServingEngine


def main() -> None:
    cfg = get_config("mixtral-8x7b").reduced()  # tiny MoE+SWA decoder on CPU
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params,
        ServeConfig(batch_slots=4, max_len=128, max_new_tokens=16, temperature=0.8),
    )
    rng = np.random.default_rng(0)
    streamed: dict[int, int] = {}

    def on_token(h, tok):  # fires as each token is harvested
        streamed[h.rid] = streamed.get(h.rid, 0) + 1

    handles = []
    for _ in range(6):  # more requests than slots -> continuous admission
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 12))
        handles.append(eng.submit(prompt.astype(np.int32), on_token=on_token))

    # block for one specific request (drives the engine), then run the rest dry
    first = handles[0].result()
    print(f"request {handles[0].rid} finished first-class: {first[:8]}...")
    results = eng.drain()
    for h in sorted(handles, key=lambda h: h.rid):
        assert h.done and results[h.rid] == h.tokens == h.result()
        assert streamed[h.rid] == len(h.tokens)
        print(f"request {h.rid}: {len(h.tokens)} tokens -> {h.tokens[:8]}...")
    assert len(results) == 6
    print("OK")


if __name__ == "__main__":
    main()
