"""Batched serving with the continuous-batching engine.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serve import ServeConfig, ServingEngine


def main() -> None:
    cfg = get_config("mixtral-8x7b").reduced()  # tiny MoE+SWA decoder on CPU
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params,
        ServeConfig(batch_slots=4, max_len=128, max_new_tokens=16, temperature=0.8),
    )
    rng = np.random.default_rng(0)
    for rid in range(6):  # more requests than slots -> continuous admission
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 12))
        eng.submit(rid, prompt.astype(np.int32))
    results = eng.run()
    for rid in sorted(results):
        print(f"request {rid}: {len(results[rid])} tokens -> {results[rid][:8]}...")
    assert len(results) == 6
    print("OK")


if __name__ == "__main__":
    main()
